"""CI guard: fail when serving throughput regresses vs a committed baseline.

Compares the ``engine="batched"`` and ``engine="scheduler"`` rows of a
fresh ``bench_serve`` JSON against
``benchmarks/baselines/serve_ci.json``, matching rows on (engine, batch):
every throughput metric the baseline row carries (``decode_tok_s`` /
``prefill_tok_s`` for the batched engine, ``goodput_tok_s`` for the
scheduler) must be at least ``(1 - max_drop)`` times the baseline value.
The scheduler row additionally carries a *structural* gate independent
of runner speed: ``goodput_vs_static`` (continuous batching vs the
static-batch baseline at the same arrival rate) must stay >=
``--min-goodput-ratio``.  The prefix-cache rows carry two more
structural gates: the warm run's ``ttft_s_p95`` must not exceed the
cold run's (``warm_ttft_p95 <= cold_ttft_p95`` — the cache must never
make TTFT worse), and the warm run's token-weighted ``prefix_hit_rate``
must stay >= ``--min-hit-rate``.  The mixed-content codec rows carry
two adaptive-selection gates, also structural (every codec row shares
the same arrival gap): ``adaptive_ratio >= max(single_codec_ratio)``
and ``adaptive_goodput >= 0.97 * best_single_goodput``.  The
``telemetry_overhead`` row gates the observability layer itself:
``traced_vs_untraced_goodput >= 0.97`` — full request tracing must stay
within 3% of the disabled-tracer fast path on the serving hot path.
The memory-hierarchy observatory adds two more structural gates: the
``prefix_warm`` row's shadow-policy hit rates must show
``shadow_sip_hit_rate >= shadow_fifo_hit_rate`` (size-indicates-reuse
retention must not lose to FIFO on the shared-prefix stream it was
built for), and the ``observatory_overhead`` row must hold
``observed_vs_plain_goodput >= 0.97`` — the full observatory (reuse
tracker + shadow simulators + audit log) priced like tracing.
The ``tier_multiturn`` row gates the host memory tier on the chat
scenario: after the device pool is fully recycled between turns, the
tiered arm's last-turn TTFT must beat the tierless cold TTFT by >2x
(``turnN_ttft_p95 <= 0.5 * cold_ttft_p95``, a same-process two-arm
ratio, so runner-speed independent), and the run must actually have
exercised the tier (nonzero demotion and promotion counters).
Exit 1 with a per-metric report otherwise.

Both the current results and the baseline are schema-stamped
(``schema_version``, written by ``bench_serve.save_json`` /
:func:`update_baseline`); a mismatch fails immediately with a
regenerate hint instead of a KeyError deep in a row comparison.
This is what keeps wins like the 21x batched decode (PR #1), the
chunked-prefill speedup (PR #2), and the continuous-batching goodput win
(PR #3) from silently rotting.

Baseline values are deliberately *derated* (stored well below locally
measured throughput) so that CI-runner speed variance does not false-fail
the gate; the guard is tuned to catch order-of-magnitude regressions —
losing jit on a hot path, reintroducing a host loop — not 20% noise.

Usage:
  PYTHONPATH=src python -m benchmarks.check_serve_regression \
      results/serve/serve_latest.json [baseline.json] [--max-drop 0.30]
  ... --update [--derate 0.25]   # regenerate the baseline from current
"""

from __future__ import annotations

import argparse
import json
import os
import sys

try:
    from benchmarks.bench_serve import SCHEMA_VERSION
except ImportError:     # run as a plain script, not -m benchmarks....
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.bench_serve import SCHEMA_VERSION

BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "serve_ci.json")
# throughput floors gated per engine kind (values scaled by the derate)
METRICS = {"batched": ("decode_tok_s", "prefill_tok_s"),
           "scheduler": ("goodput_tok_s",)}


def _gated_rows(payload: dict) -> dict[tuple[str, int], dict]:
    return {(r["engine"], r["batch"]): r for r in payload["rows"]
            if r.get("engine") in METRICS}


def _check_schema(payload: dict, what: str) -> list[str]:
    """Schema-version gate: refuse mismatched payloads up front with a
    regenerate hint rather than KeyError-ing deep in a row comparison."""
    sv = payload.get("schema_version")
    if sv == SCHEMA_VERSION:
        return []
    fix = ("re-run benchmarks.bench_serve" if what == "current results"
           else "re-run check_serve_regression --update from a fresh "
                "bench JSON")
    return [f"{what} schema_version {sv!r} != expected {SCHEMA_VERSION} "
            f"— {fix} so the row schema matches this checker"]


def check(current: dict, baseline: dict, max_drop: float,
          min_goodput_ratio: float, min_hit_rate: float) -> list[str]:
    """Return a list of failure messages (empty == pass)."""
    schema_failures = (_check_schema(current, "current results")
                       + _check_schema(baseline, "baseline"))
    if schema_failures:
        return schema_failures
    cur, base = _gated_rows(current), _gated_rows(baseline)
    failures = []
    failures += _check_prefix_rows(current, min_hit_rate)
    failures += _check_mixed_rows(current)
    failures += _check_telemetry_rows(current)
    failures += _check_observatory_rows(current)
    failures += _check_tier_rows(current)
    failures += _check_fault_counters(current)
    for key, brow in sorted(base.items()):
        engine, batch = key
        crow = cur.get(key)
        if crow is None:
            failures.append(f"{engine} batch {batch}: missing from "
                            "current results")
            continue
        # codec-labeled rows must match the baseline's codec (when the
        # baseline records one) — a bdi floor says nothing about raw/zero
        if brow.get("codec") and crow.get("codec") \
                and brow["codec"] != crow["codec"]:
            failures.append(
                f"{engine} batch {batch}: codec {crow['codec']!r} does "
                f"not match baseline codec {brow['codec']!r}")
            continue
        for metric in METRICS[engine]:
            floor = brow[metric] * (1.0 - max_drop)
            got = crow.get(metric, 0.0)
            if got < floor:
                failures.append(
                    f"{engine} batch {batch} {metric}: {got:.1f} tok/s < "
                    f"floor {floor:.1f} (baseline {brow[metric]:.1f}, "
                    f"max drop {max_drop:.0%})")
    # structural gate, runner-speed independent: continuous batching must
    # out-goodput the static-batch baseline at the same arrival rate
    for key, crow in sorted(cur.items()):
        if key[0] != "scheduler":
            continue
        ratio = crow.get("goodput_vs_static", 0.0)
        if ratio < min_goodput_ratio:
            failures.append(
                f"scheduler batch {key[1]} goodput_vs_static: {ratio:.2f} "
                f"< required {min_goodput_ratio:.2f}")
    return failures


def _check_prefix_rows(current: dict, min_hit_rate: float) -> list[str]:
    """Structural prefix-cache gates (runner-speed independent).

    ``prefix_restored`` — the warm cache snapshot/restored through
    ``serving/snapshot.py``, then serving fresh suffixes — is held to
    the same bar as ``prefix_warm``: warm hits must survive a restore
    (``restored_ttft_p95 <= cold_ttft_p95``, same minimum hit rate)."""
    cold = {r["batch"]: r for r in current["rows"]
            if r.get("engine") == "prefix_cold"}
    failures = []
    for kind in ("prefix_warm", "prefix_restored"):
        rows = {r["batch"]: r for r in current["rows"]
                if r.get("engine") == kind}
        if not rows:
            failures.append(f"{kind} row missing from current results")
        for batch, wrow in sorted(rows.items()):
            crow = cold.get(batch)
            if crow is None:
                failures.append(f"prefix_cold batch {batch}: missing")
                continue
            if wrow["ttft_s_p95"] > crow["ttft_s_p95"]:
                failures.append(
                    f"{kind} batch {batch} ttft_p95 {wrow['ttft_s_p95']:.4f}"
                    f" > cold_ttft_p95 {crow['ttft_s_p95']:.4f} (the prefix "
                    "cache made TTFT worse)")
            hit = wrow.get("prefix_hit_rate", 0.0)
            if hit < min_hit_rate:
                failures.append(
                    f"{kind} batch {batch} prefix_hit_rate: {hit:.3f} < "
                    f"required {min_hit_rate:.3f}")
            if kind == "prefix_warm":
                # shadow-policy gate: on the shared-prefix stream the
                # SIP ghost cache must at least match FIFO's hit rate —
                # the structural claim the retention policy is built on
                sip = wrow.get("shadow_sip_hit_rate")
                fifo = wrow.get("shadow_fifo_hit_rate")
                if sip is None or fifo is None:
                    failures.append(
                        f"prefix_warm batch {batch}: shadow hit rates "
                        "missing (observatory not attached to the warm "
                        "run)")
                elif sip < fifo:
                    failures.append(
                        f"prefix_warm batch {batch} shadow_sip_hit_rate "
                        f"{sip:.3f} < shadow_fifo_hit_rate {fifo:.3f} — "
                        "SIP retention losing to FIFO on its home "
                        "workload")
    return failures


# adaptive per-page codec selection must dominate the single codecs on
# the mixed-content workload: its compression ratio picks the per-page
# winner (so it can only lose the one tag byte per page), and at the
# bench's fixed arrival rate its extra candidate work must keep up with
# the offered load.  Both gates are structural — runner-speed
# independent — because every codec row shares the same arrival gap.
_MIXED_CODECS = ("bdi", "zero", "raw", "gbdi", "fpc", "adaptive")
_MIXED_GOODPUT_FRAC = 0.97


def _check_mixed_rows(current: dict) -> list[str]:
    rows = {r["codec"]: r for r in current["rows"]
            if r.get("engine") == "mixed_codec"}
    missing = [c for c in _MIXED_CODECS if c not in rows]
    if missing:
        return [f"mixed_codec rows missing for codecs: {missing}"]
    singles = [rows[c] for c in _MIXED_CODECS if c != "adaptive"]
    ad = rows["adaptive"]
    failures = []
    best_ratio = max(singles, key=lambda r: r["kv_compression_ratio"])
    if ad["kv_compression_ratio"] < best_ratio["kv_compression_ratio"]:
        failures.append(
            f"mixed adaptive kv_compression_ratio "
            f"{ad['kv_compression_ratio']:.3f} < best single "
            f"{best_ratio['kv_compression_ratio']:.3f} "
            f"({best_ratio['codec']}) — per-page selection is not "
            "picking the winning codec")
    best_good = max(singles, key=lambda r: r["goodput_tok_s"])
    floor = _MIXED_GOODPUT_FRAC * best_good["goodput_tok_s"]
    if ad["goodput_tok_s"] < floor:
        failures.append(
            f"mixed adaptive goodput_tok_s {ad['goodput_tok_s']:.1f} < "
            f"{_MIXED_GOODPUT_FRAC:.2f} * best single "
            f"{best_good['goodput_tok_s']:.1f} ({best_good['codec']}) — "
            "adaptive candidate compression is not keeping up with the "
            "offered load")
    return failures


# tracing must be nearly free on the serving hot path: the traced arm
# of the telemetry-overhead bench (full span tracer + iteration
# timeline) must hold >= this fraction of the untraced (disabled
# fast path) goodput at the same arrival rate
_TRACE_OVERHEAD_FRAC = 0.97


def _check_telemetry_rows(current: dict) -> list[str]:
    rows = [r for r in current["rows"]
            if r.get("engine") == "telemetry_overhead"]
    if not rows:
        return ["telemetry_overhead row missing from current results"]
    failures = []
    for r in rows:
        ratio = r.get("traced_vs_untraced_goodput", 0.0)
        if ratio < _TRACE_OVERHEAD_FRAC:
            failures.append(
                f"telemetry_overhead batch {r['batch']} "
                f"traced_vs_untraced_goodput: {ratio:.3f} < "
                f"{_TRACE_OVERHEAD_FRAC:.2f} — request tracing is "
                "slowing the serving hot path")
    return failures


# the full memory-hierarchy observatory (reuse tracker + four shadow
# caches + codec what-if + audit log) must stay as cheap as tracing:
# the observed arm of the observatory-overhead bench must hold >= this
# fraction of the plain engine's goodput at the same arrival rate
_OBS_OVERHEAD_FRAC = 0.97


def _check_observatory_rows(current: dict) -> list[str]:
    rows = [r for r in current["rows"]
            if r.get("engine") == "observatory_overhead"]
    if not rows:
        return ["observatory_overhead row missing from current results"]
    failures = []
    for r in rows:
        ratio = r.get("observed_vs_plain_goodput", 0.0)
        if ratio < _OBS_OVERHEAD_FRAC:
            failures.append(
                f"observatory_overhead batch {r['batch']} "
                f"observed_vs_plain_goodput: {ratio:.3f} < "
                f"{_OBS_OVERHEAD_FRAC:.2f} — the observatory is slowing "
                "the serving hot path")
    return failures


# the host memory tier must make turn-N TTFT collapse vs a cold
# re-prefill once the device pool has been recycled: both arms run in
# the same process at the same turn/prompt length, so the ratio is
# structural (runner-speed independent), and the counters prove the
# demote -> promote path actually carried the pages
_TIER_TTFT_FRAC = 0.5


def _check_tier_rows(current: dict) -> list[str]:
    rows = [r for r in current["rows"]
            if r.get("engine") == "tier_multiturn"]
    if not rows:
        return ["tier_multiturn row missing from current results"]
    failures = []
    for r in rows:
        cold, warm = r.get("cold_ttft_p95", 0.0), r.get("turnN_ttft_p95")
        if warm is None or warm > _TIER_TTFT_FRAC * cold:
            failures.append(
                f"tier_multiturn turnN_ttft_p95 {warm} > "
                f"{_TIER_TTFT_FRAC:.2f} * cold_ttft_p95 {cold:.4f} — "
                "tier promotion is not beating a cold re-prefill by >2x "
                "after a full device-pool recycle")
        for c in ("tier_demotions", "tier_promotions"):
            if r.get(c, 0) <= 0:
                failures.append(
                    f"tier_multiturn {c}: {r.get(c, 0)} == 0 — the run "
                    "never exercised the demote/promote path it claims "
                    "to measure")
        if r.get("tier_corrupt", 0) != 0:
            failures.append(
                f"tier_multiturn tier_corrupt: {r['tier_corrupt']} != 0 "
                "(host-arena integrity failures in a no-fault bench)")
    return failures


# a no-fault smoke must finish every request normally: any nonzero
# counter means the scheduler rejected, expired, retried, or requeued
# work without fault injection — a resilience-path leak into the happy
# path, which would silently distort every throughput number above
_FAULT_COUNTERS = ("rejected", "deadline_missed", "corrupt_retries",
                   "requeues")
_COUNTED_ENGINES = ("scheduler", "prefix_cold", "prefix_warm",
                    "prefix_restored", "mixed_codec",
                    "telemetry_overhead", "observatory_overhead")


def _check_fault_counters(current: dict) -> list[str]:
    failures = []
    for r in current["rows"]:
        if r.get("engine") not in _COUNTED_ENGINES:
            continue
        for c in _FAULT_COUNTERS:
            if r.get(c, 0) != 0:
                failures.append(
                    f"{r['engine']} batch {r['batch']} {c}: {r[c]} != 0 "
                    "(terminal faults / retries in a no-fault smoke)")
    return failures


def update_baseline(current: dict, path: str, derate: float) -> None:
    rows = []
    for r in current["rows"]:
        engine = r.get("engine")
        if engine not in METRICS:
            continue
        row = {"engine": engine, "batch": r["batch"]}
        if r.get("codec"):
            row["codec"] = r["codec"]
        for metric in METRICS[engine]:
            row[metric] = round(r[metric] * derate, 1)
        rows.append(row)
    payload = {
        "schema_version": SCHEMA_VERSION,
        "note": ("Derated serving-throughput floors for the CI bench-smoke "
                 "gate; values are measured tok/s scaled by the derate "
                 "factor to absorb dev-vs-CI runner speed variance (the "
                 "gate targets order-of-magnitude rots like losing jit, "
                 "not noise).  Regenerate with check_serve_regression "
                 "--update after intentional perf changes — ideally from "
                 "a bench JSON produced on an actual CI runner."),
        "derate": derate,
        "source_generated_at": current.get("generated_at"),
        "rows": rows,
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"wrote baseline {os.path.relpath(path)}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh bench_serve JSON")
    ap.add_argument("baseline", nargs="?", default=BASELINE)
    ap.add_argument("--max-drop", type=float, default=0.30,
                    help="max allowed fractional drop vs baseline")
    ap.add_argument("--min-goodput-ratio", type=float, default=1.0,
                    help="required scheduler goodput_vs_static ratio "
                         "(structural continuous-batching win)")
    ap.add_argument("--min-hit-rate", type=float, default=0.5,
                    help="required warm-run token-weighted prefix hit "
                         "rate (structural prefix-cache gate)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current results")
    ap.add_argument("--derate", type=float, default=0.10,
                    help="baseline = measured * derate (with --update); "
                         "the default absorbs dev-vs-CI runner speed gaps "
                         "— recalibrate from a CI artifact once available")
    args = ap.parse_args()

    with open(args.current) as f:
        current = json.load(f)
    if args.update:
        update_baseline(current, args.baseline, args.derate)
        return 0

    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, args.max_drop,
                     args.min_goodput_ratio, args.min_hit_rate)
    if failures:
        print("serving throughput regression detected:")
        for msg in failures:
            print(f"  FAIL {msg}")
        return 1
    cur = _gated_rows(current)
    for (engine, batch), brow in sorted(_gated_rows(baseline).items()):
        crow = cur[(engine, batch)]
        extra = ""
        if engine == "scheduler":
            extra = (f", goodput_vs_static={crow['goodput_vs_static']:.2f}"
                     f" (>= {args.min_goodput_ratio:.2f})")
        print(f"  ok {engine} batch {batch}: "
              + ", ".join(f"{m}={crow[m]:.1f} "
                          f"(floor {brow[m] * (1 - args.max_drop):.1f})"
                          for m in METRICS[engine]) + extra)
    for row in current["rows"]:
        if row.get("engine") == "prefix_warm":
            print(f"  ok prefix batch {row['batch']}: "
                  f"warm_vs_cold_ttft_p95={row['warm_vs_cold_ttft_p95']:.2f}"
                  f" (>= 1.00), prefix_hit_rate={row['prefix_hit_rate']:.3f}"
                  f" (>= {args.min_hit_rate:.3f})")
            print(f"  ok shadow batch {row['batch']}: "
                  f"sip={row['shadow_sip_hit_rate']:.3f} >= "
                  f"fifo={row['shadow_fifo_hit_rate']:.3f} "
                  f"({row['reuse_events']} reuse events)")
        elif row.get("engine") == "prefix_restored":
            print(f"  ok restored batch {row['batch']}: "
                  f"restored_vs_cold_ttft_p95="
                  f"{row['restored_vs_cold_ttft_p95']:.2f} (>= 1.00), "
                  f"prefix_hit_rate={row['prefix_hit_rate']:.3f} "
                  f"(>= {args.min_hit_rate:.3f})")
        elif row.get("engine") == "telemetry_overhead":
            print(f"  ok telemetry batch {row['batch']}: "
                  f"traced_vs_untraced_goodput="
                  f"{row['traced_vs_untraced_goodput']:.3f} "
                  f"(>= {_TRACE_OVERHEAD_FRAC:.2f}), "
                  f"trace_events={row['trace_events']}")
        elif row.get("engine") == "observatory_overhead":
            print(f"  ok observatory batch {row['batch']}: "
                  f"observed_vs_plain_goodput="
                  f"{row['observed_vs_plain_goodput']:.3f} "
                  f"(>= {_OBS_OVERHEAD_FRAC:.2f}), "
                  f"reuse_ticks={row['reuse_ticks']}, "
                  f"audit_decisions={row['audit_decisions']}")
        elif row.get("engine") == "tier_multiturn":
            print(f"  ok tier multiturn ({row['turns']} turns): "
                  f"turnN_ttft_p95={row['turnN_ttft_p95']:.4f} <= "
                  f"{_TIER_TTFT_FRAC:.2f} * cold {row['cold_ttft_p95']:.4f}"
                  f" (ratio {row['turnN_vs_cold']:.3f}), demotions="
                  f"{row['tier_demotions']}, promotions="
                  f"{row['tier_promotions']}")
        elif row.get("engine") == "mixed_summary":
            print(f"  ok mixed adaptive: ratio={row['adaptive_ratio']:.3f}"
                  f" (>= best single {row['best_single_ratio']:.3f} "
                  f"[{row['best_single_ratio_codec']}]), goodput="
                  f"{row['adaptive_goodput_tok_s']:.1f} (>= "
                  f"{_MIXED_GOODPUT_FRAC:.2f}x best single "
                  f"{row['best_single_goodput_tok_s']:.1f} "
                  f"[{row['best_single_goodput_codec']}])")
    print("  ok fault counters: rejected/deadline_missed/corrupt_retries/"
          "requeues all zero on scheduler + prefix rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
