"""Drive the full (arch x shape x mesh) dry-run matrix (deliverables e/f).

Each cell runs in a fresh subprocess (the 512-device XLA flag must precede
jax init). Results accumulate incrementally under results/dryrun/ so the
sweep is resumable; existing cells are skipped unless --force.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_dryrun [--mesh both]
      [--filter yi] [--jobs 1] [--force]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES, applicable_shapes  # noqa: E402
from repro.configs.registry import ARCHS  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def cell_id(arch: str, shape: str, mesh: str) -> str:
    return f"{arch}__{shape}__{mesh}".replace("/", "_")


def all_cells(mesh_mode: str) -> list[tuple[str, str, str]]:
    meshes = {"single": ["16x16"], "multi": ["2x16x16"],
              "both": ["16x16", "2x16x16"]}[mesh_mode]
    cells = []
    for aname, cfg in sorted(ARCHS.items()):
        for sname in SHAPES:
            for mesh in meshes:
                cells.append((aname, sname, mesh))
    return cells


def run_cell(arch: str, shape: str, mesh: str, timeout: int = 1800,
             extra: list[str] | None = None) -> dict:
    cfg = ARCHS[arch]
    if shape not in applicable_shapes(cfg):
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "skipped",
                "reason": "full-attention arch: long_500k inapplicable "
                          "(DESIGN.md §Arch-applicability)"}
    out = os.path.join(RESULTS_DIR, cell_id(arch, shape, mesh) + ".json")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", arch, "--shape", shape, "--out", out]
    if mesh == "2x16x16":
        cmd.append("--multi-pod")
    cmd += extra or []
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env)
    except subprocess.TimeoutExpired:
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "timeout", "wall_s": timeout}
    if proc.returncode != 0:
        return {"arch": arch, "shape": shape, "mesh": mesh,
                "status": "failed", "wall_s": round(time.time() - t0, 1),
                "stderr": proc.stderr[-2000:]}
    with open(out) as f:
        info = json.load(f)
    info["status"] = "ok"
    info["wall_s"] = round(time.time() - t0, 1)
    with open(out, "w") as f:
        json.dump(info, f, indent=1)
    return info


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--filter", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    cells = [c for c in all_cells(args.mesh)
             if args.filter in f"{c[0]}|{c[1]}|{c[2]}"]
    print(f"{len(cells)} cells")
    summary = []
    for i, (arch, shape, mesh) in enumerate(cells):
        out = os.path.join(RESULTS_DIR, cell_id(arch, shape, mesh) + ".json")
        if os.path.exists(out) and not args.force:
            with open(out) as f:
                info = json.load(f)
            if info.get("status") in ("ok", "skipped"):
                print(f"[{i+1}/{len(cells)}] {arch} {shape} {mesh}: cached")
                summary.append(info)
                continue
        info = run_cell(arch, shape, mesh, timeout=args.timeout)
        with open(out, "w") as f:
            json.dump(info, f, indent=1)
        print(f"[{i+1}/{len(cells)}] {arch} {shape} {mesh}: "
              f"{info['status']} ({info.get('wall_s', 0)}s)")
        summary.append(info)

    ok = sum(1 for s in summary if s["status"] == "ok")
    sk = sum(1 for s in summary if s["status"] == "skipped")
    bad = [s for s in summary if s["status"] not in ("ok", "skipped")]
    print(f"\nok={ok} skipped={sk} failed={len(bad)}")
    for s in bad:
        print("FAILED:", s["arch"], s["shape"], s["mesh"],
              s.get("stderr", "")[-300:])
    with open(os.path.join(RESULTS_DIR, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)


if __name__ == "__main__":
    main()
