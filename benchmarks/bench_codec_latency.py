"""Decompression-latency proxy (paper Table 3.5: BDI = 1 cycle vs FPC = 5).

On TPU the analogue is VPU ops per decompressed element.  We count (a)
wall-clock per-call on CPU for the jnp codec paths and (b) the op counts of
the Pallas decompressor (one fused multiply-add per element + mask unpack)
vs a serial FPC-style decoder (data-dependent per-word loop -> not even
vectorizable; we report its python-loop cost for scale).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bdi_value as bv
from repro.kernels import ops, ref


def _time(f, *args, n=20):
    f(*args)
    t0 = time.perf_counter()
    for _ in range(n):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / n


def rows() -> list[dict]:
    out = []
    x = jax.random.normal(jax.random.PRNGKey(0), (4096, 128), jnp.float32)
    p = ref.compress_ref(x)

    t_dec = _time(lambda: ops.decompress(p))
    t_comp = _time(lambda: ops.compress(x))
    t_ref_dec = _time(jax.jit(ref.decompress_ref), p)
    n_el = x.size
    out.append({"bench": "codec_latency", "op": "pallas_decompress",
                "us_per_call": round(t_dec * 1e6, 1),
                "ns_per_elem": round(t_dec / n_el * 1e9, 3)})
    out.append({"bench": "codec_latency", "op": "pallas_compress",
                "us_per_call": round(t_comp * 1e6, 1),
                "ns_per_elem": round(t_comp / n_el * 1e9, 3)})
    out.append({"bench": "codec_latency", "op": "xla_decompress",
                "us_per_call": round(t_ref_dec * 1e6, 1),
                "ns_per_elem": round(t_ref_dec / n_el * 1e9, 3)})
    # structural claim: decompression = 1 FMA + mask unpack per element
    out.append({"bench": "codec_structure", "op": "bdi_decompress",
                "vector_ops_per_elem": 2,     # unpack-and + fma
                "serial_dependencies": 0})    # fully parallel (the claim)
    out.append({"bench": "codec_structure", "op": "fpc_decompress",
                "vector_ops_per_elem": -1,
                "serial_dependencies": 1})    # variable-length words chain
    return out


def main() -> None:
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
