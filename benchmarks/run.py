"""Benchmark driver: one module per paper table/figure.

``python -m benchmarks.run`` runs every CPU-runnable paper-claim benchmark
and prints CSV rows. The dry-run matrix / roofline are separate (they need
the 512-device subprocess environment):

  python -m benchmarks.bench_dryrun        # 40 cells x 2 meshes
  python -m benchmarks.roofline            # 3-term table from the results
"""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    from benchmarks import (bench_bdi_ratio, bench_camp, bench_codec_latency,
                            bench_collectives, bench_lcp, bench_serve,
                            bench_toggle)
    suites = [
        ("bdi_ratio (Figs 3.2/3.6/3.7)", bench_bdi_ratio),
        ("codec_latency (Table 3.5)", bench_codec_latency),
        ("camp (Figs 4.8/4.9, Tab 4.3)", bench_camp),
        ("lcp (Figs 5.8/5.16/5.17)", bench_lcp),
        ("toggle+EC+MC (Figs 6.2/6.10/6.20)", bench_toggle),
        ("collective compression (DESIGN 2.4)", bench_collectives),
        ("serve throughput (§5.5.1 on the KV path)", bench_serve),
    ]
    for name, mod in suites:
        print(f"\n### {name}")
        t0 = time.time()
        mod.main()
        print(f"# {name} done in {time.time() - t0:.1f}s")

    # roofline summary if dry-run results exist
    try:
        from benchmarks import roofline
        cells = roofline.load_cells()
        if cells:
            rows = [r for r in (roofline.analyze(c) for c in cells) if r]
            print(f"\n### roofline: {len(rows)} analyzed cells "
                  f"(python -m benchmarks.roofline for the full table)")
    except Exception as e:  # noqa: BLE001
        print(f"# roofline skipped: {e}")


if __name__ == "__main__":
    main()
