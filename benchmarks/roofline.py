"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads results/dryrun/*.json (produced by bench_dryrun) and derives, per
cell:

  compute term    = HLO_FLOPs_global / (chips * 197 TFLOP/s)
  memory term     = HLO_bytes_global / (chips * 819 GB/s)
  collective term = collective_bytes_global / (chips * 50 GB/s/link)

where HLO_FLOPs/bytes come from the loop-aware per-device HLO cost model
(launch/dryrun.hlo_cost; XLA's cost_analysis undercounts while-loop bodies)
and _global = per-device x chips, so the formula reduces to
per-device / peak — the per-chip bound the hardware imposes.

Also reports MODEL_FLOPS (6*N*D for train, 2*N_active*D per decoded token)
and the usefulness ratio MODEL_FLOPS / HLO_FLOPs.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--csv out.csv]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import ARCHS, get_arch  # noqa: E402
from repro.models import transformer as T  # noqa: E402

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e class)
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link (assignment formula)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def count_params(cfg) -> tuple[float, float]:
    """(total params, active params per token) — analytic."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    h, k, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    per_layer_attn = d * h * dh + 2 * d * k * dh + h * dh * d
    if cfg.attn_kind == "mla":
        r, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                         cfg.v_head_dim)
        per_layer_attn = (d * h * (dn + dr) + d * r + d * dr
                          + r * h * dn + r * h * dv + h * dv * d)
    if cfg.family == "ssm":
        di = cfg.ssm_expand * d
        mlstm = 2 * d * di + 3 * di * di + di * 2 * cfg.n_heads + di * d
        slstm = 4 * d * d + cfg.n_heads * (d // cfg.n_heads) ** 2 * 4
        total = cfg.n_layers * (mlstm + slstm) + v * d * 2
        return float(total), float(total)   # recurrent: all params active
    ffn_dense = 3 * d * f if f else 0
    ffn_moe = 0
    ffn_moe_active = 0
    if cfg.is_moe:
        e = 3 * d * cfg.d_ff_expert
        ffn_moe = cfg.n_experts * e
        ffn_moe_active = cfg.top_k * e
        if cfg.n_shared_experts:
            shared = 3 * d * cfg.n_shared_experts * cfg.d_ff_expert
            ffn_moe += shared
            ffn_moe_active += shared
        if not cfg.moe_dense_residual:
            ffn_dense = 0
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * d
        per_layer_attn += 2 * d * di + di * d + di * (
            max(1, d // 16) + 2 * cfg.ssm_state) + di * cfg.ssm_state
    n_lyr = cfg.n_layers + cfg.enc_layers
    per_layer = per_layer_attn + ffn_dense + ffn_moe
    per_layer_active = per_layer_attn + ffn_dense + ffn_moe_active
    total = n_lyr * per_layer + 2 * v * d
    active = n_lyr * per_layer_active + 2 * v * d
    return float(total), float(active)


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs for the step (global, all chips)."""
    total, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence + attention over the cache
    flops = 2.0 * active * shape.global_batch
    if cfg.attn_kind != "none":
        wins = T.layer_windows(cfg) if cfg.local_ratio else None
        kv = cfg.n_kv_heads * cfg.head_dim
        for li in range(cfg.n_layers):
            t_eff = shape.seq_len
            if wins is not None and wins[li] > 0:
                t_eff = min(shape.seq_len, int(wins[li]))
            flops += 4.0 * shape.global_batch * t_eff * kv \
                * max(cfg.n_heads // cfg.n_kv_heads, 1)
    return flops


MICRO = {"arctic-480b": 16, "internvl2-76b": 16, "gemma3-27b": 8,
         "qwen2.5-14b": 4, "yi-9b": 4, "yi-6b": 4, "deepseek-v2-lite-16b": 2,
         "hymba-1.5b": 4, "seamless-m4t-large-v2": 2, "xlstm-350m": 1}


def analytic_bytes(cfg, shape, chips: int, cell: dict) -> float:
    """Per-device HBM traffic model (bytes/step).

    The HLO text model (hlo_bytes) overcounts fusion-wrapped in-place
    updates on CPU-XLA, so the headline memory term uses this analytic
    model: weights read per use, activations with remat recompute, KV/state
    cache read per decode step.  Constants: fwd touches each activation ~4x
    (read+write around attention/FFN), bwd ~8x incl. remat recompute.
    """
    total, active = count_params(cfg)
    param_dev = 2.0 * total / chips          # bf16, fully sharded storage
    kv_dev = 0.0
    for key in ("alias_size_in_bytes",):
        kv_dev = max(kv_dev, cell.get(key, 0))
    if shape.kind == "train":
        n_micro = MICRO.get(cfg.name, 1)
        tokens_dev = shape.global_batch * shape.seq_len / max(chips // 16, 1) \
            / 16  # dp shards only
        d = cfg.d_model
        lyr = cfg.n_layers + cfg.enc_layers
        act = lyr * tokens_dev / n_micro * d * 2 * 12 * n_micro
        weights = 3.0 * param_dev * n_micro      # fwd+bwd reads + grad write
        opt = 4.0 * param_dev                    # moments RW + param update
        return act + weights + opt
    if shape.kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / max(chips, 1) * 16
        d = cfg.d_model
        lyr = cfg.n_layers + cfg.enc_layers
        return param_dev + lyr * tokens_dev * d * 2 * 6 + kv_dev
    # decode: weights once + full cache read + tiny write
    return param_dev + kv_dev


def load_cells() -> list[dict]:
    cells = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        if f.endswith("summary.json"):
            continue
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def analyze(cell: dict) -> dict | None:
    if cell.get("status") != "ok":
        return None
    cfg = get_arch(cell["arch"])
    shape = SHAPES[cell["shape"]]
    chips = cell["n_devices"]
    # per-device quantities: flops/collectives from the loop-aware HLO cost
    # model; memory from the analytic traffic model (hlo_bytes kept as a
    # diagnostic — it overcounts fusion-wrapped in-place updates).
    fl = cell.get("hlo_flops", 0.0)
    by = analytic_bytes(cfg, shape, chips, cell)
    coll = cell.get("collectives", {}).get("total", 0)
    t_compute = fl / PEAK_FLOPS
    t_memory = by / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape)
    hlo_global = fl * chips
    mfu_at_bound = mf / (chips * PEAK_FLOPS * bound) if bound else 0.0
    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "bottleneck": bottleneck,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "hlo_bytes_dev": cell.get("hlo_bytes", 0.0),
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_frac": mfu_at_bound,
        "mem_gb": (cell.get("argument_size_in_bytes", 0)
                   + cell.get("temp_size_in_bytes", 0)) / 2**30,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = [r for r in (analyze(c) for c in load_cells()) if r]
    hdr = ("arch,shape,mesh,chips,compute_s,memory_s,collective_s,"
           "bottleneck,useful_ratio,roofline_frac,mem_gb")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['chips']},"
            f"{r['compute_s']:.3e},{r['memory_s']:.3e},"
            f"{r['collective_s']:.3e},{r['bottleneck']},"
            f"{r['useful_ratio']:.3f},{r['roofline_frac']:.4f},"
            f"{r['mem_gb']:.1f}")
    out = "\n".join(lines)
    print(out)
    if args.csv:
        with open(args.csv, "w") as f:
            f.write(out + "\n")


if __name__ == "__main__":
    main()
