"""Collective compression benchmark: wire bytes + end-to-end training
equivalence of the BDI-compressed gradient all-reduce (DESIGN.md §2.4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_arch
from repro.distributed import compress_comm as cc
from repro.models import frontends
from repro.models.api import get_model
from repro.optim import AdamWConfig, adamw_init, adamw_update

SMOKE = ShapeConfig("smoke", 16, 2, "train")


def rows() -> list[dict]:
    out = []
    # wire-byte accounting for representative gradient shapes
    for shape in ((4096, 4096), (32, 4096, 11008), (102400, 2048)):
        raw = cc.wire_bytes(shape, False)
        comp = cc.wire_bytes(shape, True)
        out.append({"bench": "collective_bytes", "shape": str(shape),
                    "raw_f32": raw, "bdi8": comp,
                    "reduction": round(raw / comp, 2)})

    # short training run: compressed vs exact DP sync loss trajectories
    cfg = get_arch("yi-6b").reduced()
    model = get_model(cfg)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    mesh = jax.make_mesh((1,), ("data",))
    upd = lambda p, g, s: adamw_update(p, g, s, ocfg)  # noqa: E731
    results = {}
    for mode, compress in (("exact", False), ("bdi8_ef", True)):
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params, ocfg)
        res = cc.init_residuals(params, 1)
        step = cc.make_dp_train_step(model.loss, upd, mesh,
                                     compress=compress)
        losses = []
        for i in range(20):
            batch = frontends.make_batch(cfg, SMOKE, jax.random.PRNGKey(i))
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, res, m = step(params, opt, res, batch)
            losses.append(float(m["loss"]))
        results[mode] = losses
    gap = abs(results["exact"][-1] - results["bdi8_ef"][-1])
    out.append({"bench": "grad_compress_train",
                "exact_final": round(results["exact"][-1], 4),
                "bdi8_final": round(results["bdi8_ef"][-1], 4),
                "final_gap": round(gap, 4),
                "exact_first": round(results["exact"][0], 4)})
    return out


def main() -> None:
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
