"""Paper claims: CAMP policy comparison (Figs 4.8/4.9, Table 4.3).

Miss rates for local (LRU/RRIP/ECM/MVE/SIP/CAMP) and global
(V-Way/G-MVE/G-SIP/G-CAMP) policies on the size<->reuse-correlated trace
and the uncorrelated (mcf-like) control trace, plus the Figure 4.1
size-aware-beats-Belady example.
"""

from __future__ import annotations

from repro.core import camp

POLICIES = ("lru", "rrip", "ecm", "mve", "sip", "camp",
            "vway", "gmve", "gsip", "gcamp")


def rows() -> list[dict]:
    out = []
    cap = 32 << 10
    tr_corr = camp.soplex_like_trace(n_epochs=16)
    tr_unc = camp.mcf_like_trace(n=30_000)
    for name, tr in (("soplex_like", tr_corr), ("mcf_like", tr_unc)):
        for p in POLICIES:
            r = camp.run_policy(tr, p, capacity_bytes=cap)
            out.append({"bench": "camp", "trace": name, "policy": p,
                        "miss_rate": round(r["miss_rate"], 4)})
    # Fig 4.1 example
    tr, cap41 = camp.fig_4_1_trace()
    for p in ("belady", "mve"):
        r = camp.run_policy(tr, p, capacity_bytes=cap41)
        out.append({"bench": "camp_fig41", "trace": "fig4.1", "policy": p,
                    "miss_rate": round(r["miss_rate"], 4),
                    "misses": r["misses"]})
    # compressed vs uncompressed effective capacity (Fig 3.14 flavor)
    tr = camp.mcf_like_trace(n=30_000, working_set=3_000)
    for name, t in (("compressed", tr),
                    ("uncompressed", [(a, 64) for a, _ in tr])):
        r = camp.run_policy(t, "rrip", capacity_bytes=64 << 10)
        out.append({"bench": "camp_capacity", "trace": name,
                    "policy": "rrip",
                    "miss_rate": round(r["miss_rate"], 4)})
    return out


def main() -> None:
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
