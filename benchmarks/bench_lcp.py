"""Paper claims: LCP compression ratio + overflows (Figs 5.8, 5.16, 5.17)
adapted to tensor pages, plus the KV-page compression the serving path uses.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lcp
from repro.kernels import ref


def rows() -> list[dict]:
    out = []
    key = jax.random.PRNGKey(0)

    # page populations mirroring the thesis' data-pattern mix, in value space
    def smooth(key, n=64, ln=128):
        b = 100 + 10 * jax.random.normal(key, (n, 1))
        return b + 1e-3 * jax.random.normal(key, (n, ln))

    pops = {
        "zeros": jnp.zeros((64, 128)),
        "repeated": jnp.full((64, 128), 3.0),
        "smooth_ldr": smooth(key),
        "gaussian": jax.random.normal(key, (64, 128)) * 2,
        "mixed": jnp.concatenate([jnp.zeros((16, 128)),
                                  smooth(key, 32),
                                  jax.random.normal(key, (16, 128)) * 1e4]),
    }
    for name, lines in pops.items():
        for rtol in (0.05, 1e-4):
            p = lcp.compress_page(lines.astype(jnp.float32), exc_slots=8,
                                  raw_rtol=rtol)
            out.append({
                "bench": "lcp", "population": name, "rtol": rtol,
                "ratio_vs_bf16": round(float(
                    lcp.page_compression_ratio(p)), 3),
                "exceptions": int(p.n_exc),
                "overflow": bool(p.overflow),
            })

    # type-1 overflow rate under random line updates (Fig 5.16 flavor)
    lines = smooth(jax.random.PRNGKey(1))
    page = lcp.compress_page(lines.astype(jnp.float32), exc_slots=8,
                             raw_rtol=1e-4)
    t1 = 0
    for i in range(32):
        wild = jax.random.normal(jax.random.PRNGKey(i + 2), (128,)) * 2
        page, flag = lcp.write_line(page, jnp.int32(i % 64), wild,
                                    raw_rtol=1e-4)
        t1 += int(flag)
    out.append({"bench": "lcp_overflow", "population": "smooth+updates",
                "type1_overflows": t1, "page_overflow": bool(page.overflow)})

    # KV-page compression (single-base form the decode kernel reads)
    k = jax.random.normal(jax.random.PRNGKey(3), (16, 4, 16, 128))
    pages = ref.compress_kv_pages(k, k * 0.5)
    raw = k.size * 2 * 2                      # k+v bf16
    comp = (pages.kd.size + pages.vd.size
            + 4 * 2 * np.prod(pages.kb.shape))
    err = float(jnp.abs(ref.dequant_pages(pages.kd, pages.kb, pages.ks)
                        - k).max())
    out.append({"bench": "kv_pages", "population": "gauss_kv",
                "ratio_vs_bf16": round(raw / comp, 3),
                "max_abs_err": round(err, 5)})
    return out


def main() -> None:
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
