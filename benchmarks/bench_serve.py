"""Serving throughput: batched device-resident engine vs the seed engine.

Measures prefill and decode tokens/sec through the LCP-paged
compressed-KV engine at batch 1/8/32 and writes a machine-readable JSON
snapshot to ``results/serve/`` so the perf trajectory is tracked across
PRs.  The headline row is decode tok/s at batch 8: the batched jitted
hot path must hold >=5x over the host-looped reference (it lands ~15x on
CPU; more where compiled Pallas is available).

Run: PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "results", "serve")

PROMPT_LEN = 12
PAGE = 8


def _build(cfg, params, engine: str, batch: int, pool: int):
    if engine == "batched":
        from repro.serving.engine import PagedKVEngine
        return PagedKVEngine(cfg, params, page_size=PAGE,
                             n_pool_pages=pool, max_batch=batch)
    from repro.serving.reference import ReferencePagedKVEngine
    return ReferencePagedKVEngine(cfg, params, page_size=PAGE,
                                  n_pool_pages=pool)


def _bench_engine(cfg, params, engine: str, batch: int,
                  decode_steps: int) -> dict:
    pool = max(256, batch * 16)
    eng = _build(cfg, params, engine, batch, pool)
    prompts = {i: [1 + (i * 7 + j) % (cfg.vocab - 1)
                   for j in range(PROMPT_LEN)] for i in range(batch)}

    t0 = time.time()
    for sid, p in prompts.items():
        eng.add_request(sid, p)
    prefill_s = time.time() - t0

    if engine == "batched":
        eng.decode_batch()                       # trace/compile warmup
        t0 = time.time()
        for _ in range(decode_steps):
            eng.decode_batch()
        decode_s = time.time() - t0
    else:
        for sid in prompts:                      # symmetric warmup step
            eng.decode_one(sid)
        t0 = time.time()
        for _ in range(decode_steps):
            for sid in prompts:
                eng.decode_one(sid)
        decode_s = time.time() - t0

    return {
        "bench": "serve", "engine": engine, "batch": batch,
        "prompt_len": PROMPT_LEN, "decode_steps": decode_steps,
        "prefill_tok_s": round(batch * PROMPT_LEN / prefill_s, 1),
        "decode_tok_s": round(batch * decode_steps / decode_s, 1),
        "kv_compression_ratio": round(eng.compression_ratio(), 3),
    }


def rows(quick: bool = False) -> list[dict]:
    import jax

    from repro.configs.registry import get_arch
    from repro.models.api import get_model

    cfg = get_arch("yi-6b").reduced(n_layers=2, d_model=64)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batches = (1, 8) if quick else (1, 8, 32)
    out = []
    for batch in batches:
        # reference is ~15x slower per token: fewer timed steps there
        batched = _bench_engine(cfg, params, "batched", batch,
                                decode_steps=8 if quick else 32)
        refr = _bench_engine(cfg, params, "reference", batch,
                             decode_steps=4 if quick else 8)
        speed = round(batched["decode_tok_s"] / refr["decode_tok_s"], 2)
        batched["decode_speedup_vs_reference"] = speed
        out.extend([batched, refr])
    return out


def save_json(rs: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(RESULTS_DIR, f"serve_{stamp}.json")
    payload = {"generated_at": stamp, "rows": rs}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(os.path.join(RESULTS_DIR, "serve_latest.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return path


def main(quick: bool = False) -> None:
    rs = rows(quick=quick)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    path = save_json(rs)
    print(f"# wrote {os.path.relpath(path)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch 1/8 only, fewer timed steps")
    main(quick=ap.parse_args().quick)
