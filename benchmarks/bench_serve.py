"""Serving throughput: batched device-resident engine vs the seed engine.

Measures prefill and decode tokens/sec through the LCP-paged
compressed-KV engine at batch 1/8/32 and writes a machine-readable JSON
snapshot to ``results/serve/`` so the perf trajectory is tracked across
PRs.  Two headline rows, both at batch 8: decode tok/s through the
batched jitted hot path (>=5x over the host-looped reference; ~15-20x on
CPU) and — new with chunked prefill — prefill tok/s through the
chunked-batch admission path (>=5x over per-request host-loop prefill).

Each engine is warmed on a throwaway instance first so the timed numbers
measure steady-state throughput, not jit tracing (the jit cache is
global, so the timed instance reuses the warm traces).

Run: PYTHONPATH=src python -m benchmarks.bench_serve [--quick | --smoke]
CI:  the ``bench-smoke`` job runs ``--smoke`` and gates the batched rows
against ``benchmarks/baselines/serve_ci.json`` (check_serve_regression).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "results", "serve")

PROMPT_LEN = 12
PAGE = 8

# (batches, batched decode steps, reference decode steps)
_MODES = {
    "full": ((1, 8, 32), 32, 8),
    "quick": ((1, 8), 8, 4),
    "smoke": ((1, 8), 6, 3),
}


def _build(cfg, params, engine: str, batch: int, pool: int):
    if engine == "batched":
        from repro.serving.engine import PagedKVEngine
        return PagedKVEngine(cfg, params, page_size=PAGE,
                             n_pool_pages=pool, max_batch=batch)
    from repro.serving.reference import ReferencePagedKVEngine
    return ReferencePagedKVEngine(cfg, params, page_size=PAGE,
                                  n_pool_pages=pool)


def _prompts(cfg, batch: int) -> dict[int, list[int]]:
    return {i: [1 + (i * 7 + j) % (cfg.vocab - 1)
                for j in range(PROMPT_LEN)] for i in range(batch)}


def _bench_engine(cfg, params, engine: str, batch: int,
                  decode_steps: int) -> dict:
    pool = max(256, batch * 16)
    prompts = _prompts(cfg, batch)

    warm = _build(cfg, params, engine, batch, pool)   # pays jit tracing
    warm.add_requests(prompts)
    if engine == "batched":
        for _ in range(PAGE):    # through a tail fill -> publish is traced
            warm.decode_batch()
    else:
        warm.decode_one(0)
    del warm      # free its pools; the jit trace cache is global

    eng = _build(cfg, params, engine, batch, pool)
    t0 = time.time()
    eng.add_requests(prompts)
    prefill_s = time.time() - t0

    if engine == "batched":
        eng.decode_batch()                       # steady-state entry step
        t0 = time.time()
        for _ in range(decode_steps):
            eng.decode_batch()
        decode_s = time.time() - t0
    else:
        for sid in prompts:                      # symmetric warmup step
            eng.decode_one(sid)
        t0 = time.time()
        for _ in range(decode_steps):
            for sid in prompts:
                eng.decode_one(sid)
        decode_s = time.time() - t0

    return {
        "bench": "serve", "engine": engine, "batch": batch,
        "prompt_len": PROMPT_LEN, "decode_steps": decode_steps,
        "prefill_mode": "chunked" if engine == "batched" else "host-loop",
        "prefill_tok_s": round(batch * PROMPT_LEN / prefill_s, 1),
        "decode_tok_s": round(batch * decode_steps / decode_s, 1),
        "kv_compression_ratio": round(eng.compression_ratio(), 3),
    }


def rows(mode: str = "full") -> list[dict]:
    import jax

    from repro.configs.registry import get_arch
    from repro.models.api import get_model

    cfg = get_arch("yi-6b").reduced(n_layers=2, d_model=64)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batches, bat_steps, ref_steps = _MODES[mode]
    out = []
    for batch in batches:
        # reference is ~15x slower per token: fewer timed steps there
        batched = _bench_engine(cfg, params, "batched", batch, bat_steps)
        refr = _bench_engine(cfg, params, "reference", batch, ref_steps)
        batched["decode_speedup_vs_reference"] = round(
            batched["decode_tok_s"] / refr["decode_tok_s"], 2)
        batched["prefill_speedup_vs_reference"] = round(
            batched["prefill_tok_s"] / refr["prefill_tok_s"], 2)
        out.extend([batched, refr])
    return out


def save_json(rs: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(RESULTS_DIR, f"serve_{stamp}.json")
    payload = {"generated_at": stamp, "rows": rs}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(os.path.join(RESULTS_DIR, "serve_latest.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return path


def main(mode: str = "full") -> None:
    rs = rows(mode=mode)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    path = save_json(rs)
    print(f"# wrote {os.path.relpath(path)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch 1/8 only, fewer timed steps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sizes (implies --quick)")
    args = ap.parse_args()
    main(mode="smoke" if args.smoke else "quick" if args.quick else "full")
