"""Serving throughput: batched device-resident engine vs the seed engine.

Measures prefill and decode tokens/sec through the LCP-paged
compressed-KV engine at batch 1/8/32 and writes a machine-readable JSON
snapshot to ``results/serve/`` so the perf trajectory is tracked across
PRs.  Two headline rows, both at batch 8: decode tok/s through the
batched jitted hot path (>=5x over the host-looped reference; ~15-20x on
CPU) and — new with chunked prefill — prefill tok/s through the
chunked-batch admission path (>=5x over per-request host-loop prefill).

Each engine is warmed on a throwaway instance first so the timed numbers
measure steady-state throughput, not jit tracing (the jit cache is
global, so the timed instance reuses the warm traces).

Also runs the **open-loop scheduler benchmark**: requests arrive at a
fixed rate (gap scaled to measured iteration time so "same load" holds
on any runner) into the continuous-batching token-budget scheduler vs a
static-batch baseline that drains each batch before admitting the next.
Reports per-request TTFT / latency percentiles and goodput (completed
tok/s); ``goodput_vs_static`` is the headline continuous-batching win.

And the **shared-system-prompt prefix-cache benchmark**: the same
open-loop workload — every prompt = one shared system prefix + a short
unique suffix — runs cold (no prefix cache), warm (cache primed by
one priming request), and *restored* (the warm engine snapshot/restored
through ``serving/snapshot.py``, then served fresh suffixes), reporting
the token-weighted prefix hit rate and the warm-vs-cold p95 TTFT ratio.
CI gates the structural ``warm_ttft_p95 <= cold_ttft_p95`` win (and the
same for the restored row — warm hits must survive a restore), a
minimum hit rate, and — on every scheduler-driven row — that the
resilience counters (rejected / deadline_missed / corrupt_retries /
requeues) are all zero in this no-fault smoke.

Every row is labeled with the KV page codec in use (``--codec`` /
``REPRO_CODEC``; default bdi) and its measured compression ratio, so
``results/serve/`` JSONs stay comparable across PRs and codecs.

Finally the **mixed-content codec benchmark**: one scheduler-driven run
per registered codec (bdi/zero/raw/gbdi/fpc/adaptive) over a workload
that interleaves zero-heavy, low-dynamic-range, and incompressible
prompts — content classes that favor *different* codecs — so adaptive
per-page selection has something real to select over.  CI gates the
structural wins ``adaptive_ratio >= max(single_codec_ratio)`` and
``adaptive_goodput >= 0.97 * best_single_goodput``.

And the **telemetry-overhead bench**: the scheduler workload replayed
with full request tracing on vs off (the disabled-tracer fast path),
reporting ``traced_vs_untraced_goodput`` — CI gates >= 0.97, pinning
the observability layer's cost on the serving hot path.  The
**observatory-overhead bench** holds the memory-hierarchy observatory
(``serving/observatory.py``: reuse tracking + shadow policy/codec
simulators + decision audit) to the same bar:
``observed_vs_plain_goodput >= 0.97`` at the same fixed arrival rate.
The prefix-cache warm run additionally attaches an observatory, so its
row reports shadow-policy hit rates (CI gates shadow-SIP >=
shadow-FIFO on the shared-prefix stream), the run prints the joint
size-bin × reuse-distance table, and ``results/serve/`` gains the
decision-audit JSONL (``audit_smoke.jsonl``) and the rendered
``launch/observe.py`` report (``observe_smoke.txt``) as CI artifacts.  The traced run
exports ``results/serve/trace_smoke.json`` (Chrome trace_event /
Perfetto), ``metrics_smoke.prom`` and ``metrics_smoke.jsonl`` as CI
artifacts.  Per-request TTFT / inter-token / latency percentiles on
scheduler rows come from the scheduler's own registry histograms
(``serving/telemetry.py``) rather than a parallel recomputation, so the
bench reports exactly what the exporters export.  Every JSON payload is
stamped with ``schema_version`` (:data:`SCHEMA_VERSION`), the git
revision, and the codec set; ``check_serve_regression`` refuses a
payload whose schema version does not match its own.

Run: PYTHONPATH=src python -m benchmarks.bench_serve [--quick | --smoke]
CI:  the ``bench-smoke`` job runs ``--smoke`` and gates the batched +
scheduler + prefix rows against ``benchmarks/baselines/serve_ci.json``
(check_serve_regression).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..",
                           "results", "serve")

# results/serve/ payload schema: bump when row fields or payload keys
# change shape; check_serve_regression refuses mismatched payloads
# (stdlib-importable — keep this module's top level free of jax imports)
# v3: prefix_warm rows carry shadow-policy hit rates + reuse counts;
#     new observatory_overhead row gates observed_vs_plain_goodput
# v4: new tier_multiturn row (host-tier chat scenario): per-turn TTFT
#     for a tiered vs tierless arm with the device pool recycled
#     between turns, plus tier demotion/promotion counters
SCHEMA_VERSION = 4

PROMPT_LEN = 12
PAGE = 8

# (batches, batched decode steps, reference decode steps)
_MODES = {
    "full": ((1, 8, 32), 32, 8),
    "quick": ((1, 8), 8, 4),
    "smoke": ((1, 8), 6, 3),
}

# open-loop scheduler benchmark: (n_requests, engine slots)
_SCHED_MODES = {
    "full": (12, 4),
    "quick": (8, 3),
    "smoke": (8, 3),
}
SCHED_BUDGET = 24

# telemetry-overhead bench: workload replication factor and best-of-N
# trials per arm.  Like the mixed-codec bench, both arms run at a
# fixed under-loaded arrival rate (gap = loaded per-request time x
# MIXED_GAP_FACTOR): raw drag-race goodput on a CI runner drifts far
# more than the 3% gate (frequency scaling, co-tenancy), while at a
# fixed offered load the span is pinned by the arrival schedule and
# the ratio only moves if tracing slows request *latency* — the
# structural question the gate actually asks
_OVERHEAD_REPS = 2
_OVERHEAD_TRIALS = 3

# shared-system-prompt prefix-cache benchmark: (n_requests, engine slots)
_PREFIX_MODES = {
    "full": (10, 4),
    "quick": (8, 3),
    "smoke": (8, 3),
}
SYS_PROMPT_LEN = 41          # 5 cached pages of 8 + tail; suffixes are short

# mixed-content codec benchmark: (n_requests, engine slots); the codec
# sweep is fixed — adaptive must beat every single-codec run on ratio
# and stay within 3% of the best single on goodput (CI gates both)
_MIXED_MODES = {
    "full": (9, 3),
    "quick": (9, 3),
    "smoke": (9, 3),
}
# arrival gap = loaded per-request time x this: under-load headroom so
# every codec keeps up and the drain tail (one request's latency, the
# only codec-dependent part of the span) stays ~1/((n_req-1)*factor)
# of the measured span — well inside the 0.97 goodput gate
MIXED_GAP_FACTOR = 8.0
MIXED_CODECS = ("bdi", "zero", "raw", "gbdi", "fpc", "adaptive")

# host-tier multi-turn chat benchmark: (turns, timed reps).  Both arms
# recycle the entire device pool between turns; only the tiered arm can
# bring the conversation's pages back without re-prefilling, so the
# warm/cold TTFT ratio isolates exactly what the tier buys
_TIER_MODES = {
    "full": (6, 3),
    "quick": (6, 2),
    "smoke": (6, 2),
}
TIER_SEED_PROMPT = 96        # 12 pages; grows ~2 pages per turn
TIER_GEN = 8
TIER_HOST_MB = 8


def _build(cfg, params, engine: str, batch: int, pool: int,
           codec: str | None = None):
    if engine == "batched":
        from repro.serving.engine import PagedKVEngine
        return PagedKVEngine(cfg, params, page_size=PAGE,
                             n_pool_pages=pool, max_batch=batch,
                             codec=codec)
    from repro.serving.reference import ReferencePagedKVEngine
    return ReferencePagedKVEngine(cfg, params, page_size=PAGE,
                                  n_pool_pages=pool, codec=codec)


def _prompts(cfg, batch: int) -> dict[int, list[int]]:
    return {i: [1 + (i * 7 + j) % (cfg.vocab - 1)
                for j in range(PROMPT_LEN)] for i in range(batch)}


def _bench_engine(cfg, params, engine: str, batch: int,
                  decode_steps: int, codec: str | None = None) -> dict:
    pool = max(256, batch * 16)
    prompts = _prompts(cfg, batch)

    warm = _build(cfg, params, engine, batch, pool, codec)  # jit tracing
    warm.add_requests(prompts)
    if engine == "batched":
        for _ in range(PAGE):    # through a tail fill -> publish is traced
            warm.decode_batch()
    else:
        warm.decode_one(0)
    del warm      # free its pools; the jit trace cache is global

    eng = _build(cfg, params, engine, batch, pool, codec)
    t0 = time.perf_counter()
    eng.add_requests(prompts)
    prefill_s = time.perf_counter() - t0

    if engine == "batched":
        eng.decode_batch()                       # steady-state entry step
        t0 = time.perf_counter()
        for _ in range(decode_steps):
            eng.decode_batch()
        decode_s = time.perf_counter() - t0
    else:
        for sid in prompts:                      # symmetric warmup step
            eng.decode_one(sid)
        t0 = time.perf_counter()
        for _ in range(decode_steps):
            for sid in prompts:
                eng.decode_one(sid)
        decode_s = time.perf_counter() - t0

    return {
        "bench": "serve", "engine": engine, "batch": batch,
        "codec": eng.codec.name,
        "prompt_len": PROMPT_LEN, "decode_steps": decode_steps,
        "prefill_mode": "chunked" if engine == "batched" else "host-loop",
        "prefill_tok_s": round(batch * PROMPT_LEN / prefill_s, 1),
        "decode_tok_s": round(batch * decode_steps / decode_s, 1),
        "kv_compression_ratio": round(eng.compression_ratio(), 3),
    }


def _sched_workload(cfg, n_req: int) -> list[dict]:
    """Deterministic convoy-prone open-loop workload: ragged prompts and
    bimodal generation lengths (one long straggler per slot-group), the
    shape under which static batching pays its convoy tax.  Prompt
    lengths stay <= prefill_chunk so every cohort lands in one scratch
    length bucket — cohort row count is then the only jit-shape degree
    of freedom, and :func:`_warm_sched_shapes` can cover it exactly."""
    return [{"rid": i,
             "prompt": [1 + (i * 7 + j) % (cfg.vocab - 1)
                        for j in range(8 + (i * 5) % 9)],
             "max_new": 12 if i % 3 == 0 else 3}
            for i in range(n_req)]


def _warm_sched_shapes(cfg, params, slots: int, pool: int,
                       codec: str | None = None) -> None:
    """Trace every dispatch shape the open-loop runs can hit, so the
    timed runs measure steady state rather than jit compilation.

    Arrival timing decides how requests group into cohorts, so the timed
    run's cohort sizes are not predictable — warm them all: mixed
    (decode + k-row cohort) for every k possible while a slot decodes,
    and prefill-only admission for every k up to the slot count."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.scheduler import ContinuousScheduler

    for k in range(1, slots + 1):
        if k < slots:                 # mixed: one slot kept decoding
            eng = PagedKVEngine(cfg, params, page_size=PAGE,
                                n_pool_pages=pool, max_batch=slots,
                                codec=codec)
            sched = ContinuousScheduler(eng, token_budget=SCHED_BUDGET)
            sched.submit(-1, [1, 2, 3], max_new_tokens=40)
            while sched.tracks[-1].state != "running":
                sched.step()
            for i in range(k):
                sched.submit(i, [1 + i] * 16, max_new_tokens=2)
            sched.run()
        eng = PagedKVEngine(cfg, params, page_size=PAGE,
                            n_pool_pages=pool, max_batch=slots,
                            codec=codec)
        eng.add_requests({i: [1 + i] * 16 for i in range(k)})
        eng.decode_batch()


def _percentile(xs: list[float], q: float) -> float:
    ys = sorted(xs)
    i = min(len(ys) - 1, int(round(q * (len(ys) - 1))))
    return ys[i]


def _req_metrics(t0: float, arrivals: list[float], firsts: list[float],
                 finishes: list[float], n_tokens: int) -> dict:
    ttft = [f - a for f, a in zip(firsts, arrivals)]
    lat = [f - a for f, a in zip(finishes, arrivals)]
    span = max(finishes) - t0
    return {
        "goodput_tok_s": round(n_tokens / span, 1),
        "ttft_s_mean": round(sum(ttft) / len(ttft), 4),
        "ttft_s_p95": round(_percentile(ttft, 0.95), 4),
        "latency_s_p50": round(_percentile(lat, 0.50), 4),
        "latency_s_p95": round(_percentile(lat, 0.95), 4),
    }


def _run_continuous(cfg, params, reqs, gap: float, slots: int,
                    pool: int, engine=None,
                    codec: str | None = None, tel=None) -> dict:
    """Open-loop drive of the continuous scheduler: request i arrives at
    ``i * gap`` seconds; admit/retire between iterations.  ``engine``
    lets the prefix-cache scenario reuse a primed engine+cache; ``tel``
    lets the telemetry-overhead bench pass a tracing-enabled
    ``Telemetry`` shared by engine and scheduler.

    TTFT / inter-token / request-latency percentiles are read from the
    scheduler's registry histograms (``serving/telemetry.py``) — the
    same series the Prometheus/JSONL exporters publish — instead of a
    parallel host-side recomputation.  Goodput stays host-derived (the
    span from drive start to last retirement is a property of the whole
    run, not of any one request's histogram sample); all timestamps
    share the one monotonic ``perf_counter`` clock the scheduler
    stamps ``Track`` times with."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.scheduler import ContinuousScheduler
    from repro.serving.telemetry import Telemetry

    if engine is not None:
        eng = engine
    else:
        if tel is None:
            tel = Telemetry()
        eng = PagedKVEngine(cfg, params, page_size=PAGE,
                            n_pool_pages=pool, max_batch=slots,
                            codec=codec, telemetry=tel)
    sched = ContinuousScheduler(eng, token_budget=SCHED_BUDGET,
                                telemetry=tel)
    t0 = time.perf_counter()
    arrivals = {r["rid"]: t0 + r["rid"] * gap for r in reqs}
    pending = {r["rid"]: r for r in reqs}
    while pending or not sched.idle:
        now = time.perf_counter()
        for rid, r in list(pending.items()):
            if arrivals[rid] <= now:
                sched.submit(rid, r["prompt"], max_new_tokens=r["max_new"])
                del pending[rid]
        if sched.idle and pending:
            time.sleep(max(0.0, min(arrivals[r] for r in pending)
                           - time.perf_counter()))
            continue
        sched.step()
    fin = sched.finished()
    order = [r["rid"] for r in reqs]
    reg = sched.telemetry.registry
    cn = eng.codec.name
    h_ttft = reg.histogram("serve_ttft_seconds", codec=cn)
    h_lat = reg.histogram("serve_request_latency_seconds", codec=cn)
    h_tok = reg.histogram("serve_intertoken_seconds", codec=cn)
    n_tokens = sum(len(fin[r].out_tokens) for r in order)
    span = max(fin[r].finished_t for r in order) - t0
    m = {
        "goodput_tok_s": round(n_tokens / span, 1),
        "ttft_s_mean": round(h_ttft.mean, 4),
        "ttft_s_p95": round(h_ttft.quantile(0.95), 4),
        "latency_s_p50": round(h_lat.quantile(0.50), 4),
        "latency_s_p95": round(h_lat.quantile(0.95), 4),
        "intertoken_s_p50": round(h_tok.quantile(0.50), 4),
    }
    m["mixed_iterations"] = sched.stats["mixed_iterations"]
    m["iterations"] = sched.stats["iterations"]
    # resilience counters (serving/faults.py): a no-fault bench run must
    # report all four as zero — check_serve_regression gates this, so a
    # scheduler change that silently rejects/retries/expires requests
    # can't masquerade as a goodput win
    m["rejected"] = sched.stats["rejected"]
    m["deadline_missed"] = sched.stats["deadline_missed"]
    m["corrupt_retries"] = sched.stats["corrupt_retries"]
    m["requeues"] = sched.stats["requeues"]
    m["codec"] = eng.codec.name
    m["kv_compression_ratio"] = round(eng.compression_ratio(), 3)
    return m


def _run_static(cfg, params, reqs, gap: float, slots: int,
                pool: int, codec: str | None = None) -> dict:
    """Static-batch baseline at the same arrival rate: form a batch from
    whatever has arrived (up to ``slots``), prefill it, decode until the
    *whole batch* drains, release, repeat — the phase-wise convoy the
    scheduler exists to kill."""
    from repro.serving.engine import PagedKVEngine

    eng = PagedKVEngine(cfg, params, page_size=PAGE, n_pool_pages=pool,
                        max_batch=slots, codec=codec)
    t0 = time.perf_counter()
    arrivals = {r["rid"]: t0 + r["rid"] * gap for r in reqs}
    queue = list(reqs)
    firsts: dict[int, float] = {}
    finishes: dict[int, float] = {}
    n_tokens = 0
    while queue:
        now = time.perf_counter()
        arrived = [r for r in queue if arrivals[r["rid"]] <= now]
        if not arrived:
            time.sleep(max(0.0, min(arrivals[r["rid"]] for r in queue)
                           - time.perf_counter()))
            continue
        batch = arrived[:slots]
        queue = [r for r in queue if r not in batch]
        eng.add_requests({r["rid"]: r["prompt"] for r in batch})
        remaining = {r["rid"]: r["max_new"] for r in batch}
        produced = {r["rid"]: 0 for r in batch}
        while remaining:
            out = eng.decode_batch(list(remaining))
            now = time.perf_counter()
            for rid in out:
                produced[rid] += 1
                n_tokens += 1
                firsts.setdefault(rid, now)
            for rid in list(remaining):
                if produced[rid] >= remaining[rid]:
                    finishes[rid] = now
                    del remaining[rid]
        for r in batch:
            eng.release(r["rid"])
    order = [r["rid"] for r in reqs]
    m = _req_metrics(t0, [arrivals[r] for r in order],
                     [firsts[r] for r in order],
                     [finishes[r] for r in order], n_tokens)
    m["codec"] = eng.codec.name
    m["kv_compression_ratio"] = round(eng.compression_ratio(), 3)
    return m


def _sys_prompt(cfg) -> list[int]:
    """The one shared system prompt (priming and workload must agree)."""
    return [1 + (j * 7) % (cfg.vocab - 1) for j in range(SYS_PROMPT_LEN)]


def _prefix_workload(cfg, n_req: int, salt: int) -> list[dict]:
    """Shared-system-prompt open-loop workload: every prompt is one
    shared ``SYS_PROMPT_LEN``-token prefix plus a short unique suffix
    (``salt`` varies the suffixes so the warm-up pass does not seed the
    timed pass's suffix pages — only the system prefix is shared)."""
    return [{"rid": i,
             "prompt": _sys_prompt(cfg)
             + [1 + (salt + i * 13 + j) % (cfg.vocab - 1)
                for j in range(2 + i % 4)],
             "max_new": 3}
            for i in range(n_req)]


def _primed_engine(cfg, params, slots: int, pool: int,
                   codec: str | None = None, telemetry=None,
                   observatory=None):
    """Engine with a prefix cache primed by one system-prompt request."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.prefix_cache import PrefixCache

    cache = PrefixCache.for_model(cfg, PAGE)
    eng = PagedKVEngine(cfg, params, page_size=PAGE, n_pool_pages=pool,
                        max_batch=slots, prefix_cache=cache, codec=codec,
                        telemetry=telemetry, observatory=observatory)
    eng.add_requests({-1: _sys_prompt(cfg) + [1]})
    eng.release(-1)          # pages stay cache-retained
    return eng


def _warm_prefix_shapes(cfg, params, slots: int, pool: int,
                        codec: str | None = None) -> None:
    """Trace every dispatch shape the prefix-bench open-loop runs can
    hit (arrival timing decides cohort row counts, so warm them all:
    mixed and prefill-only cohorts of every size, cold and warm-start,
    plus the warm-scratch fill)."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.scheduler import ContinuousScheduler

    for primed in (False, True):
        for k in range(1, slots + 1):
            eng = (_primed_engine(cfg, params, slots, pool, codec)
                   if primed
                   else PagedKVEngine(cfg, params, page_size=PAGE,
                                      n_pool_pages=pool, max_batch=slots,
                                      codec=codec))
            sched = ContinuousScheduler(eng, token_budget=SCHED_BUDGET)
            if k < slots:             # mixed: one slot kept decoding
                sched.submit(-2, _prefix_workload(cfg, 1, 6000)[0]["prompt"],
                             max_new_tokens=60)
                while sched.tracks[-2].state != "running":
                    sched.step()
            for r in _prefix_workload(cfg, k, 6100 + 61 * k):
                sched.submit(r["rid"], r["prompt"],
                             max_new_tokens=r["max_new"])
            sched.run()


def _bench_prefix(cfg, params, mode: str,
                  codec: str | None = None) -> list[dict]:
    """Warm vs cold TTFT under a shared system prompt.

    Cold = no prefix cache (every request prefills the full prompt);
    warm = cache primed with the system prefix, so every request's
    prefill shrinks to its suffix (TTFT-bound workload: 3 output
    tokens).  Both runs see the same arrival gap."""
    n_req, slots = _PREFIX_MODES[mode]
    pool = 256

    _warm_prefix_shapes(cfg, params, slots, pool, codec)
    t0 = time.perf_counter()
    _run_continuous(cfg, params, _prefix_workload(cfg, n_req, 9000), 0.0,
                    slots, pool, codec=codec)
    gap = (time.perf_counter() - t0) / max(1, n_req) * 0.5

    from repro.serving.observatory import Observatory
    from repro.serving.telemetry import Telemetry

    reqs = _prefix_workload(cfg, n_req, 0)
    cold = _run_continuous(cfg, params, reqs, gap, slots, pool,
                           codec=codec)
    # the warm arm carries the memory-hierarchy observatory: the
    # shared-prefix stream is where shadow retention policies separate
    # (SIP must keep the hot system pages — CI gates shadow-SIP >=
    # shadow-FIFO) and where real cross-request reuse distances exist
    tel = Telemetry()
    obs = Observatory(tel)
    warm_eng = _primed_engine(cfg, params, slots, pool, codec,
                              telemetry=tel, observatory=obs)
    warm = _run_continuous(cfg, params, reqs, gap, slots, pool,
                           engine=warm_eng, tel=tel)
    hit_rate = warm_eng.prefix_cache.hit_rate()

    # snapshot/restore warm-hit scenario: persist the warm engine + its
    # cache trie, restore into a fresh engine, and serve a NEW suffix
    # salt — only the system prefix can hit, so warm TTFT surviving a
    # restore is exactly what this row measures (CI gates
    # restored_ttft_p95 <= cold_ttft_p95)
    import tempfile

    from repro.serving.snapshot import restore_snapshot, save_snapshot
    with tempfile.TemporaryDirectory() as td:
        save_snapshot(td, warm_eng, step=0)
        rest_eng, _ = restore_snapshot(td, cfg, params)
    restored = _run_continuous(cfg, params, _prefix_workload(cfg, n_req, 77),
                               gap, slots, pool, engine=rest_eng)
    rest_hits = rest_eng.prefix_cache.hit_rate()

    cold.update({"bench": "serve_prefix", "engine": "prefix_cold",
                 "batch": slots, "n_requests": n_req,
                 "sys_prompt_len": SYS_PROMPT_LEN})
    shadow = obs.shadow.hit_rates()
    joint = obs.reuse.joint_counts()
    warm.update({
        "bench": "serve_prefix", "engine": "prefix_warm", "batch": slots,
        "n_requests": n_req, "sys_prompt_len": SYS_PROMPT_LEN,
        "prefix_hit_rate": round(hit_rate, 3),
        # structural headline: warm admission skips the cached prefix
        "warm_vs_cold_ttft_p95": round(
            cold["ttft_s_p95"] / max(warm["ttft_s_p95"], 1e-9), 2),
        # counterfactual retention policies over the same access stream
        # (check_serve_regression gates sip >= fifo)
        "shadow_hit_rates": {p: round(v, 3) for p, v in shadow.items()},
        "shadow_sip_hit_rate": round(shadow["sip"], 3),
        "shadow_fifo_hit_rate": round(shadow["fifo"], 3),
        "reuse_events": int(sum(joint.values())),
    })
    # observatory artifacts for CI: the decision-audit JSONL and the
    # rendered observe.py report, from the warm shared-prefix run
    from repro.launch.observe import render_report
    os.makedirs(RESULTS_DIR, exist_ok=True)
    obs.audit.to_jsonl(os.path.join(RESULTS_DIR, "audit_smoke.jsonl"))
    with open(os.path.join(RESULTS_DIR, "observe_smoke.txt"), "w") as f:
        f.write(render_report(tel.registry.snapshot(),
                              audit_records=obs.audit.records))
    print(f"# prefix_warm shadow hit rates: "
          + ", ".join(f"{p}={v:.3f}" for p, v in shadow.items()))
    print("# prefix_warm size-bin x reuse-distance:")
    for ln in obs.reuse_table().splitlines():
        print(f"#   {ln}")
    restored.update({
        "bench": "serve_prefix", "engine": "prefix_restored",
        "batch": slots, "n_requests": n_req,
        "sys_prompt_len": SYS_PROMPT_LEN,
        "prefix_hit_rate": round(rest_hits, 3),
        "restored_vs_cold_ttft_p95": round(
            cold["ttft_s_p95"] / max(restored["ttft_s_p95"], 1e-9), 2),
    })
    return [warm, cold, restored]


def _bench_scheduler(cfg, params, mode: str,
                     codec: str | None = None) -> list[dict]:
    """Open-loop arrival benchmark: continuous scheduler vs static batch
    at the same arrival rate."""
    n_req, slots = _SCHED_MODES[mode]
    pool = 256
    reqs = _sched_workload(cfg, n_req)

    # warm every cohort/dispatch shape on throwaway instances (jit cache
    # is global), then both full paths for the publish-size variants
    _warm_sched_shapes(cfg, params, slots, pool, codec)
    _run_continuous(cfg, params, reqs, 0.0, slots, pool, codec=codec)
    _run_static(cfg, params, reqs, 0.0, slots, pool, codec)

    # arrival gap scaled to measured iteration time so "same arrival
    # rate" means the same *relative* load on any runner speed
    t0 = time.perf_counter()
    _run_continuous(cfg, params, reqs, 0.0, slots, pool, codec=codec)
    iter_s = (time.perf_counter() - t0) / max(1, n_req)
    gap = iter_s * 0.5

    cont = _run_continuous(cfg, params, reqs, gap, slots, pool,
                           codec=codec)
    stat = _run_static(cfg, params, reqs, gap, slots, pool, codec)
    cont.update({
        "bench": "serve_sched", "engine": "scheduler", "batch": slots,
        "n_requests": n_req, "token_budget": SCHED_BUDGET,
        "goodput_vs_static": round(cont["goodput_tok_s"]
                                   / stat["goodput_tok_s"], 2),
        # tail TTFT is where the convoy effect lives; mean TTFT can favor
        # static (its first batch prefills at full width, un-budgeted)
        "ttft_p95_vs_static": round(stat["ttft_s_p95"]
                                    / max(cont["ttft_s_p95"], 1e-9), 2),
    })
    stat.update({"bench": "serve_sched", "engine": "static",
                 "batch": slots, "n_requests": n_req})
    return [cont, stat]


def _bench_telemetry(cfg, params, mode: str,
                     codec: str | None = None) -> list[dict]:
    """Tracing-overhead bench: the scheduler workload replayed with the
    request tracer fully enabled vs on its disabled fast path, at the
    same open-loop arrival rate.  Must run after
    :func:`_bench_scheduler` (it reuses the jit shapes warmed there).

    Both arms run at the *same fixed under-loaded arrival rate* (the
    mixed-codec bench's framing — see :data:`MIXED_GAP_FACTOR`): CI
    goodput in a saturated drag race drifts far more than the 3% gate,
    but at a fixed offered load the span is pinned by the arrival
    schedule, so the ratio is structural — it only moves if tracing
    slows per-request latency enough to stall the drain.  Each arm
    additionally takes its best-of-``_OVERHEAD_TRIALS``, arms
    alternating so slow process drift hits both equally; the gate asks
    "is tracing cheap", not "is this run lucky".  The traced arm's
    artifacts — Chrome trace, Prometheus text, JSONL metrics — are
    written to ``results/serve/`` so CI uploads real exporter output
    from a real run, and check_serve_regression gates
    ``traced_vs_untraced_goodput >= 0.97``."""
    from repro.serving.telemetry import Telemetry

    n_req, slots = _SCHED_MODES[mode]
    pool = 256
    reqs = _sched_workload(cfg, _OVERHEAD_REPS * n_req)

    t0 = time.perf_counter()
    _run_continuous(cfg, params, reqs, 0.0, slots, pool, codec=codec)
    gap = ((time.perf_counter() - t0) / max(1, len(reqs))
           * MIXED_GAP_FACTOR)

    # discard one pair: the first at-rate runs absorb residual process
    # warmup (allocator growth, lazy imports), which would deflate
    # whichever arm happens to run first
    _run_continuous(cfg, params, reqs, gap, slots, pool, codec=codec)
    _run_continuous(cfg, params, reqs, gap, slots, pool, codec=codec,
                    tel=Telemetry(trace=True))

    untraced_runs, traced_runs = [], []
    for _ in range(_OVERHEAD_TRIALS):
        untraced_runs.append(
            _run_continuous(cfg, params, reqs, gap, slots, pool,
                            codec=codec))
        tel = Telemetry(trace=True)
        traced_runs.append(
            (_run_continuous(cfg, params, reqs, gap, slots, pool,
                             codec=codec, tel=tel), tel))
    untraced = max(untraced_runs, key=lambda m: m["goodput_tok_s"])
    traced, tel = max(traced_runs, key=lambda e: e[0]["goodput_tok_s"])

    os.makedirs(RESULTS_DIR, exist_ok=True)
    tel.tracer.write_chrome_trace(
        os.path.join(RESULTS_DIR, "trace_smoke.json"))
    with open(os.path.join(RESULTS_DIR, "metrics_smoke.prom"), "w") as f:
        f.write(tel.registry.to_prometheus())
    with open(os.path.join(RESULTS_DIR, "metrics_smoke.jsonl"), "w") as f:
        f.write(tel.registry.to_jsonl_line(final=True) + "\n")

    row = dict(traced)
    row.update({
        "bench": "serve_telemetry", "engine": "telemetry_overhead",
        "batch": slots, "n_requests": len(reqs),
        "token_budget": SCHED_BUDGET,
        "trace_events": len(tel.tracer.events),
        "trace_slices": len(tel.tracer.slices),
        "traced_goodput_tok_s": traced["goodput_tok_s"],
        "untraced_goodput_tok_s": untraced["goodput_tok_s"],
        "traced_vs_untraced_goodput": round(
            traced["goodput_tok_s"]
            / max(untraced["goodput_tok_s"], 1e-9), 3),
    })
    return [row]


def _bench_observatory(cfg, params, mode: str,
                       codec: str | None = None) -> list[dict]:
    """Observatory-overhead bench: the scheduler workload with the full
    memory-hierarchy observatory attached (reuse tracker + four shadow
    caches + codec what-if + audit log) vs a plain engine, at the same
    fixed under-loaded arrival rate.  Same framing and best-of-N
    discipline as :func:`_bench_telemetry` (and must run after it — the
    jit shapes are shared); check_serve_regression gates
    ``observed_vs_plain_goodput >= 0.97``, the issue's "observatory-
    enabled goodput >= 0.97x untraced" acceptance bar."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.observatory import Observatory
    from repro.serving.telemetry import Telemetry

    n_req, slots = _SCHED_MODES[mode]
    pool = 256
    reqs = _sched_workload(cfg, _OVERHEAD_REPS * n_req)

    def observed_engine():
        tel = Telemetry()
        obs = Observatory(tel)
        eng = PagedKVEngine(cfg, params, page_size=PAGE,
                            n_pool_pages=pool, max_batch=slots,
                            codec=codec, telemetry=tel, observatory=obs)
        return eng, tel

    t0 = time.perf_counter()
    _run_continuous(cfg, params, reqs, 0.0, slots, pool, codec=codec)
    gap = ((time.perf_counter() - t0) / max(1, len(reqs))
           * MIXED_GAP_FACTOR)

    # discard pair (residual warmup), then alternate best-of-N arms
    _run_continuous(cfg, params, reqs, gap, slots, pool, codec=codec)
    eng, tel = observed_engine()
    _run_continuous(cfg, params, reqs, gap, slots, pool, engine=eng,
                    tel=tel)

    plain_runs, observed_runs = [], []
    for _ in range(_OVERHEAD_TRIALS):
        plain_runs.append(
            _run_continuous(cfg, params, reqs, gap, slots, pool,
                            codec=codec))
        eng, tel = observed_engine()
        observed_runs.append(
            (_run_continuous(cfg, params, reqs, gap, slots, pool,
                             engine=eng, tel=tel), eng))
    plain = max(plain_runs, key=lambda m: m["goodput_tok_s"])
    observed, eng = max(observed_runs,
                        key=lambda e: e[0]["goodput_tok_s"])

    row = dict(observed)
    row.update({
        "bench": "serve_observatory", "engine": "observatory_overhead",
        "batch": slots, "n_requests": len(reqs),
        "token_budget": SCHED_BUDGET,
        "reuse_ticks": eng.obs.reuse.tick,
        "audit_decisions": sum(eng.obs.audit.counts().values()),
        "observed_goodput_tok_s": observed["goodput_tok_s"],
        "plain_goodput_tok_s": plain["goodput_tok_s"],
        "observed_vs_plain_goodput": round(
            observed["goodput_tok_s"]
            / max(plain["goodput_tok_s"], 1e-9), 3),
    })
    return [row]


def _zeroed_token_params(params, tok: int):
    """Zero one embedding row so prompt runs of ``tok`` produce
    exactly-zero K/V rows at every layer (RMSNorm has no additive bias,
    RoPE(0)=0, projections are bias-free) — real zero-page content for
    the mixed-content workload, not synthetic pool writes."""
    p = dict(params)
    emb = dict(params["embed"])
    emb["w"] = params["embed"]["w"].at[tok].set(0)
    p["embed"] = emb
    return p


def _mixed_workload(cfg, n_req: int, zt: int) -> list[dict]:
    """Deterministic mixed-content workload cycling three prompt
    classes, each favoring a different page codec:

    * **zero-heavy** — a 2-page run of the zeroed token plus a short
      unique tail: the zero codec's best case (pages collapse to the
      bitmap), unreachable for bdi/gbdi which pay their header floor.
    * **low-dynamic-range** — a 4-token vocabulary: K/V rows cluster
      around few anchor values, so delta codecs (gbdi > bdi) win.
    * **incompressible** — full-vocab pseudo-random tokens: dense,
      high-entropy pages where raw's zero-overhead storage is hard to
      beat and every compressing codec pays its metadata.

    No single codec wins all three; adaptive should match the best
    per page (plus one tag byte)."""
    reqs = []
    lo = (5, 9, 2, 7)
    for i in range(n_req):
        cls = i % 3
        if cls == 0:
            prompt = [zt] * (2 * PAGE + 1) + [
                1 + (i * 11 + j) % (cfg.vocab - 1) for j in range(2)]
        elif cls == 1:
            prompt = [zt] * 4 + [lo[(i + j) % 4] for j in range(15)]
        else:
            prompt = [1 + (i * 31 + j * 17) % (cfg.vocab - 1)
                      for j in range(19)]
        reqs.append({"rid": i, "prompt": prompt,
                     "max_new": 6 if cls == 0 else 8})
    return reqs


def _warm_mixed_shapes(cfg, params, slots: int, pool: int,
                       codec: str) -> None:
    """Per-codec jit-shape warm for the mixed bench (the jit cache is
    keyed on the codec singleton, so every codec traces its own set):
    mixed and prefill-only cohorts of every row count, using the
    workload's own prompt classes so each codec's publish path is
    traced on real zero / low-range / dense content."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.scheduler import ContinuousScheduler

    reqs = _mixed_workload(cfg, 2 * slots, cfg.vocab - 2)
    for k in range(1, slots + 1):
        if k < slots:                 # mixed: one slot kept decoding
            eng = PagedKVEngine(cfg, params, page_size=PAGE,
                                n_pool_pages=pool, max_batch=slots,
                                codec=codec)
            sched = ContinuousScheduler(eng, token_budget=SCHED_BUDGET)
            sched.submit(-1, reqs[0]["prompt"], max_new_tokens=40)
            while sched.tracks[-1].state != "running":
                sched.step()
            for i in range(k):
                sched.submit(i, reqs[i + 1]["prompt"], max_new_tokens=2)
            sched.run()
        eng = PagedKVEngine(cfg, params, page_size=PAGE,
                            n_pool_pages=pool, max_batch=slots,
                            codec=codec)
        eng.add_requests({i: reqs[i]["prompt"] for i in range(k)})
        eng.decode_batch()


def _bench_mixed(cfg, params, mode: str) -> list[dict]:
    """Adaptive per-page codec selection vs every single codec on the
    mixed-content workload.

    One scheduler-driven run per codec in :data:`MIXED_CODECS`, all at
    the *same open-loop arrival rate* (gap scaled to a measured loaded
    pass, with under-load headroom).  Goodput at a fixed arrival rate
    is the honest serving comparison for codecs that trade compute for
    bytes: the question is whether adaptive's extra candidate work
    keeps up with the offered load, not how it places in a fully
    saturated drag race (where tiny-model jit dispatch noise exceeds
    the codec deltas).  Emits one ``mixed_codec`` row per codec plus a
    ``mixed_summary`` row; check_serve_regression gates
    ``adaptive_ratio >= max(single_codec_ratio)`` and
    ``adaptive_goodput >= 0.97 * best_single`` from the per-codec
    rows (the compression ratios are content-deterministic; only the
    goodputs need the rate-controlled framing)."""
    n_req, slots = _MIXED_MODES[mode]
    pool = 256
    zt = cfg.vocab - 2
    zp = _zeroed_token_params(params, zt)
    reqs = _mixed_workload(cfg, n_req, zt)

    # arrival gap from a loaded bdi pass, with headroom so every codec
    # (gbdi/fpc/adaptive publish more candidate work) keeps up
    _warm_mixed_shapes(cfg, zp, slots, pool, "bdi")
    t0 = time.perf_counter()
    _run_continuous(cfg, zp, reqs, 0.0, slots, pool, codec="bdi")
    gap = (time.perf_counter() - t0) / max(1, n_req) * MIXED_GAP_FACTOR

    out = []
    for codec in MIXED_CODECS:
        if codec != "bdi":
            _warm_mixed_shapes(cfg, zp, slots, pool, codec)
        # settle pass at the timed gap: arrival timing decides cohort
        # grouping, so this traces any at-rate shape the explicit warm
        # missed before the timed pass runs
        _run_continuous(cfg, zp, reqs, gap, slots, pool, codec=codec)
        row = _run_continuous(cfg, zp, reqs, gap, slots, pool, codec=codec)
        row.update({"bench": "serve_mixed", "engine": "mixed_codec",
                    "batch": slots, "n_requests": n_req, "zero_token": zt,
                    "arrival_gap_s": round(gap, 4)})
        out.append(row)

    singles = [r for r in out if r["codec"] != "adaptive"]
    ad = next(r for r in out if r["codec"] == "adaptive")
    best_ratio = max(singles, key=lambda r: r["kv_compression_ratio"])
    best_good = max(singles, key=lambda r: r["goodput_tok_s"])
    out.append({
        "bench": "serve_mixed", "engine": "mixed_summary", "batch": slots,
        "n_requests": n_req,
        "adaptive_ratio": ad["kv_compression_ratio"],
        "best_single_ratio": best_ratio["kv_compression_ratio"],
        "best_single_ratio_codec": best_ratio["codec"],
        "adaptive_goodput_tok_s": ad["goodput_tok_s"],
        "best_single_goodput_tok_s": best_good["goodput_tok_s"],
        "best_single_goodput_codec": best_good["codec"],
        "adaptive_vs_best_single_goodput": round(
            ad["goodput_tok_s"] / max(best_good["goodput_tok_s"], 1e-9), 3),
    })
    return out


def _run_chat(cfg, params, turns: int, *, tiered: bool,
              codec: str | None = None) -> tuple[list[float], dict]:
    """One multi-turn conversation with the device pool fully recycled
    between turns.  Returns (per-turn TTFT seconds, tier stats).

    The conversation is deterministic (greedy decode, fixed user
    tokens), so the tiered and tierless arms see identical prompts at
    every turn — tier promotion round-trips bit-identical pages, making
    the decoded replies (and therefore turn N+1's prompt) match too."""
    from repro.serving.engine import PagedKVEngine
    from repro.serving.prefix_cache import PrefixCache
    from repro.serving.tier import TieredPageStore

    cache = PrefixCache.for_model(cfg, PAGE)
    eng = PagedKVEngine(cfg, params, page_size=PAGE, n_pool_pages=256,
                        max_batch=1, codec=codec, prefix_cache=cache,
                        cache_decode_pages=True)
    tier = None
    if tiered:
        tier = TieredPageStore.for_model(cfg, PAGE, eng.codec,
                                         host_mb=TIER_HOST_MB)
        eng.attach_tier(tier)
    convo = [1 + (j * 7) % (cfg.vocab - 1) for j in range(TIER_SEED_PROMPT)]
    ttfts = []
    for t in range(1, turns + 1):
        t0 = time.perf_counter()
        eng.add_requests({t: convo})
        toks = [eng.decode_one(t)]
        ttfts.append(time.perf_counter() - t0)
        toks += [eng.decode_one(t) for _ in range(TIER_GEN - 1)]
        eng.release(t)
        eng.recycle_device_pool()
        convo = convo + toks + [1 + (t * 13 + j) % (cfg.vocab - 1)
                                for j in range(8)]
    return ttfts, (dict(tier.stats) if tier is not None else {})


def _bench_tier(cfg, params, mode: str, codec: str | None = None
                ) -> list[dict]:
    """Host-tier multi-turn chat benchmark (one ``tier_multiturn`` row).

    The structural claim under test: after the device pool is fully
    recycled, a turn-N prompt re-admitted through the tier promotes its
    prefix from host RAM instead of re-prefilling, so its TTFT must
    beat the tierless cold TTFT by >2x (check_serve_regression gates
    ``turnN_ttft_p95 <= 0.5 * cold_ttft_p95``).  The ratio is between
    two arms of the same process at the same turn/prompt length, so it
    is insensitive to the absolute speed of the CI runner."""
    turns, reps = _TIER_MODES[mode]
    # throwaway rep per arm: jit-traces every per-turn prefill shape and
    # the tier's gather/scatter paths, so the timed reps are steady-state
    _run_chat(cfg, params, turns, tiered=False, codec=codec)
    _run_chat(cfg, params, turns, tiered=True, codec=codec)
    cold_runs = [_run_chat(cfg, params, turns, tiered=False, codec=codec)[0]
                 for _ in range(reps)]
    warm_runs, tier_stats = [], {}
    for _ in range(reps):
        tt, st = _run_chat(cfg, params, turns, tiered=True, codec=codec)
        warm_runs.append(tt)
        tier_stats = st
    cold_last = [r[-1] for r in cold_runs]
    warm_last = [r[-1] for r in warm_runs]
    cold_p95 = _percentile(cold_last, 0.95)
    warm_p95 = _percentile(warm_last, 0.95)
    from repro.codecs.base import resolve
    return [{
        "bench": "serve_tier", "engine": "tier_multiturn",
        "codec": resolve(codec).name, "turns": turns, "reps": reps,
        "seed_prompt_len": TIER_SEED_PROMPT, "gen": TIER_GEN,
        "tier_host_mb": TIER_HOST_MB,
        "cold_ttft_p95": round(cold_p95, 4),
        "turnN_ttft_p95": round(warm_p95, 4),
        "turnN_vs_cold": round(warm_p95 / max(cold_p95, 1e-9), 3),
        "per_turn_ttft_cold": [round(x, 4) for x in cold_runs[-1]],
        "per_turn_ttft_warm": [round(x, 4) for x in warm_runs[-1]],
        "tier_demotions": tier_stats.get("demotions", 0),
        "tier_promotions": tier_stats.get("promotions", 0),
        "tier_corrupt": tier_stats.get("corrupt", 0),
    }]


def rows(mode: str = "full", codec: str | None = None) -> list[dict]:
    import jax

    from repro.configs.registry import get_arch
    from repro.models.api import get_model

    cfg = get_arch("yi-6b").reduced(n_layers=2, d_model=64)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batches, bat_steps, ref_steps = _MODES[mode]
    out = []
    for batch in batches:
        # reference is ~15x slower per token: fewer timed steps there
        batched = _bench_engine(cfg, params, "batched", batch, bat_steps,
                                codec)
        refr = _bench_engine(cfg, params, "reference", batch, ref_steps,
                             codec)
        batched["decode_speedup_vs_reference"] = round(
            batched["decode_tok_s"] / refr["decode_tok_s"], 2)
        batched["prefill_speedup_vs_reference"] = round(
            batched["prefill_tok_s"] / refr["prefill_tok_s"], 2)
        out.extend([batched, refr])
    out.extend(_bench_scheduler(cfg, params, mode, codec))
    out.extend(_bench_telemetry(cfg, params, mode, codec))
    out.extend(_bench_observatory(cfg, params, mode, codec))
    out.extend(_bench_prefix(cfg, params, mode, codec))
    # the mixed-content bench sweeps MIXED_CODECS itself (it is the
    # adaptive-vs-single-codec comparison), so --codec does not apply
    out.extend(_bench_mixed(cfg, params, mode))
    out.extend(_bench_tier(cfg, params, mode, codec))
    return out


def _git_rev() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except OSError:
        return "unknown"


def save_json(rs: list[dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(RESULTS_DIR, f"serve_{stamp}.json")
    payload = {"schema_version": SCHEMA_VERSION,
               "generated_at": stamp,
               "git_rev": _git_rev(),
               "codecs": sorted({r["codec"] for r in rs if "codec" in r}),
               "rows": rs}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    with open(os.path.join(RESULTS_DIR, "serve_latest.json"), "w") as f:
        json.dump(payload, f, indent=2)
    return path


def main(mode: str = "full", codec: str | None = None) -> None:
    rs = rows(mode=mode, codec=codec)
    for r in rs:
        print(",".join(f"{k}={v}" for k, v in r.items()))
    path = save_json(rs)
    print(f"# wrote {os.path.relpath(path)}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="batch 1/8 only, fewer timed steps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI sizes (implies --quick)")
    ap.add_argument("--codec", default=None,
                    help="KV page codec for every engine in the bench "
                         "(bdi | zero | raw | gbdi | fpc | adaptive; "
                         "default: REPRO_CODEC or bdi) — rows carry the "
                         "codec name + measured compression ratio so "
                         "trajectories stay comparable across PRs (the "
                         "mixed-content rows sweep all codecs "
                         "regardless)")
    args = ap.parse_args()
    main(mode="smoke" if args.smoke else "quick" if args.quick else "full",
         codec=args.codec)
