"""Paper claims: bit-toggle increase under compression + EC/MC recovery
(Figs 6.2, 6.10, 6.20).
"""

from __future__ import annotations

import numpy as np

from repro.core import bdi_exact as bx
from repro.core import patterns, toggle


def rows() -> list[dict]:
    out = []
    pops = {
        "narrow": patterns.narrow_lines(2048, seed=0),
        "ldr": patterns.ldr_lines(2048, seed=1),
        "thesis_mix": patterns.thesis_mix(2048, seed=2),
        "random": patterns.random_lines(2048, seed=3),
    }
    for name, lines in pops.items():
        # interleaved serialization = the naive wire format of Fig 6.2
        stats = toggle.ec_stream(lines, e_toggle=4.0, e_byte=1.0,
                                 consolidated=False)
        raw_t = max(stats["raw_toggles"], 1)
        per_byte_raw = raw_t / max(stats["raw_bytes"], 1)
        per_byte_comp = stats["comp_toggles"] / max(stats["comp_bytes"], 1)
        out.append({
            "bench": "toggle", "population": name,
            "comp_ratio": round(stats["comp_ratio"], 3),
            "toggle_increase_total": round(stats["comp_toggles"] / raw_t, 3),
            "toggle_increase_per_byte": round(
                per_byte_comp / max(per_byte_raw, 1e-12), 3),
            "ec_toggle_increase": round(stats["ec_toggles"] / raw_t, 3),
            "ec_ratio": round(stats["ec_ratio"], 3),
            "ec_compressed_frac": round(stats["ec_compressed_frac"], 3),
        })
    # Metadata Consolidation effect (Fig 6.20)
    for name in ("narrow", "ldr"):
        c = bx.bdi_compress(pops[name])
        ti = toggle.toggle_count(toggle.serialize_interleaved(c))
        tc = toggle.toggle_count(toggle.serialize_consolidated(c))
        out.append({"bench": "toggle_mc", "population": name,
                    "interleaved_toggles": ti, "consolidated_toggles": tc,
                    "mc_reduction": round(1 - tc / max(ti, 1), 3)})
    return out


def main() -> None:
    for r in rows():
        print(",".join(f"{k}={v}" for k, v in r.items()))


if __name__ == "__main__":
    main()
