"""Paper claims: compression ratios (Figs 3.2, 3.6, 3.7; Table 3.6).

Columns: population, algorithm, effective compression ratio (2x-tag cache,
1-byte segments — the paper's accounting, Sec 3.7).
"""

from __future__ import annotations

import numpy as np

from repro.core import bdi_exact as bx
from repro.core import patterns, prior

N_LINES = 8192


def rows() -> list[dict]:
    out = []
    pops = {
        "thesis_mix": patterns.thesis_mix(N_LINES, seed=0),
        "zeros": patterns.zeros_lines(N_LINES),
        "repeated": patterns.repeated_lines(N_LINES, seed=1),
        "narrow": patterns.narrow_lines(N_LINES, seed=2),
        "ldr": patterns.ldr_lines(N_LINES, seed=3),
        "pointer_table": patterns.pointer_table_lines(N_LINES, seed=4),
        "mixed_two_range": patterns.mixed_two_range_lines(N_LINES, seed=5),
        "random": patterns.random_lines(N_LINES, seed=6),
    }
    for pname, lines in pops.items():
        sizes = prior.all_algorithm_sizes(lines)
        for alg, s in sizes.items():
            out.append({"bench": "bdi_ratio", "population": pname,
                        "alg": alg,
                        "ratio": round(bx.effective_ratio(s), 3)})
    # Figure 3.6: number-of-bases sweep on the thesis mix
    lines = pops["thesis_mix"]
    for k in (0, 1, 2, 3, 4, 8):
        r = bx.effective_ratio(bx.bplusdelta_sizes(lines, n_bases=k))
        out.append({"bench": "bases_sweep", "population": "thesis_mix",
                    "alg": f"bplusdelta_{k}bases", "ratio": round(r, 3)})
    return out


def main() -> None:
    for r in rows():
        print(f"{r['bench']},{r['population']},{r['alg']},{r['ratio']}")


if __name__ == "__main__":
    main()
